"""Ring-engine benchmark (DESIGN.md §12): ring vs xla × wire dtype ×
bucket counts — wall-clock, HLO op counts, wire bytes, peak memory.

Sections (all committed to ``BENCH_ring.json``):

  1. **Schedule wall-clock** (subprocess, 8 forced host devices): the
     RS+AG round via ``rps_exchange_plan`` per engine × {f32, bf16 wire}
     × bucket counts. On this CPU host the "ring" engine is the
     interpret ppermute ring — 2(n−1) sequential hops per bucket vs the
     xla engine's 2 fused collectives, so CPU ring wall-clock is
     *expected to lose*; it is reported as-is and labelled by backend.
     The fused single-dispatch TPU lowering (where the ring wins by
     overlapping DMA with the masked accumulate) cannot execute here —
     its lowering is validated in section 2 instead.
  2. **HLO counts** (``tools.check_hlo``): CPU lowering op counts per
     engine (ring: 2(n−1)·buckets collective-permutes, zero RS/AG;
     xla: 2·buckets collectives), and the **TPU export** of the fused
     kernel round: exactly 1 ``tpu_custom_call`` per bucket, zero
     StableHLO collectives — the tentpole claim, checked through the
     real Mosaic pipeline.
  3. **Wire bytes**: ``plan.wire_bytes`` at f32 vs bf16 RS — the bf16
     wire halves the RS leg (the acceptance's RS-bytes claim; AG leg
     unchanged, it moves the payload dtype).
  4. **Peak memory, ~100M simulator step**: compile-level peak
     (args + outputs + temps − donated aliases) for the donated vs
     undonated step — the measured ≥20% reduction from
     ``donate_argnums`` + the global-path copy elimination.
  5. **Simulator exchange wall-clock**: ``rps_exchange_global`` per
     engine/wire on one device (xla einsum vs ring-order scan replay).

Run:  PYTHONPATH=src python -m benchmarks.ring_bench [--quick] \
          [--out BENCH_ring.json]
"""
import argparse
import json
import os
import subprocess
import sys
import textwrap

N_WORKERS = 8
DROP = 0.1
SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
ROOT = os.path.dirname(SRC)


def _tree(n, leaves=6, rows=192, cols=128):
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(0)
    return {f"p{i}": jnp.asarray(rng.normal(size=(n, rows, cols)),
                                 jnp.float32) for i in range(leaves)}


from benchmarks.exchange_bench import _min_of_batches  # noqa: E402
# (one timing harness for both exchange benches — warmup/min-of-batches
# methodology fixes land in exactly one place)


# ---------------------------------------------------------------------------
# 1. collective-schedule wall-clock + CPU HLO counts (subprocess)
# ---------------------------------------------------------------------------

def bench_schedule(reps, iters, quick):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
        import sys, json
        sys.path.insert(0, %r); sys.path.insert(0, %r)
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.core import plan as plan_lib, rps
        from repro.telemetry.timing import time_fn
        from repro.train.trainer import _shard_map
        from tools import check_hlo

        n, reps, iters = %d, %d, %d
        mesh = Mesh(np.array(jax.devices()).reshape(n), ("data",))
        rng = np.random.default_rng(0)
        tree = {f"p{i}": jnp.asarray(rng.normal(size=(n, 192, 128)),
                                     jnp.float32) for i in range(6)}
        per_worker = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)
        specs = jax.tree.map(lambda _: P("data"), per_worker)
        key = jax.random.PRNGKey(0)

        def exchange_fn(plan, engine, dt):
            def body(t, k):
                sq = jax.tree.map(lambda x: x[0], t)
                out = rps.rps_exchange_plan(sq, k, %r, "data", plan=plan,
                                            engine=engine, rs_dtype=dt)
                return jax.tree.map(lambda x: x[None], out)
            return jax.jit(_shard_map(body, mesh, (specs, P()), specs,
                                      {"data"}))

        res = {"ms": {}, "hlo": {}}
        for nb in (1, 2):
            plan = plan_lib.make_plan(per_worker, n, n_buckets=nb)
            for engine in ("xla", "ring"):
                for dt, dname in ((jnp.float32, "f32"),
                                  (jnp.bfloat16, "bf16")):
                    name = f"{engine}_b{nb}_{dname}"
                    f = exchange_fn(plan, engine, dt)
                    txt = f.lower(tree, key).as_text()
                    res["hlo"][name] = check_hlo.summarize(txt)
                    res["ms"][name] = time_fn(f, tree, key, reps=reps,
                                              iters=iters, warmup=2) * 1e3
        print("RESULT " + json.dumps(res))
    """) % (N_WORKERS, SRC, ROOT, N_WORKERS, reps, iters, DROP)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1200 if quick else 2400)
    if r.returncode != 0:
        raise RuntimeError(f"schedule bench subprocess failed:\n"
                           f"{r.stdout}\n{r.stderr}")
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


# ---------------------------------------------------------------------------
# 2. TPU export: the fused-dispatch claim
# ---------------------------------------------------------------------------

def bench_tpu_export(n_buckets=2):
    import jax
    import jax.numpy as jnp
    sys.path.insert(0, ROOT)
    from tools import check_hlo
    from repro.kernels import rps_ring
    try:
        from jax import export
    except ImportError:
        return {"available": False}
    n, k, W = N_WORKERS, 2, 256
    S = k * n

    def round_fn(*tables):
        pos = jnp.zeros((1,), jnp.int32)
        left = jnp.full((1,), n - 1, jnp.int32)
        right = jnp.ones((1,), jnp.int32)
        return [rps_ring.ring_bucket_fused(
            t, jnp.ones((S, 1), jnp.bfloat16), jnp.ones((S, 1)),
            jnp.full((S, 1), float(n), jnp.bfloat16), pos, left, right,
            n=n, k=k, mode="model", rs_dtype=jnp.bfloat16,
            collective_id=cid) for cid, t in enumerate(tables)]

    args = [jnp.zeros((S, W), jnp.float32) for _ in range(n_buckets)]
    txt = export.export(jax.jit(round_fn), platforms=("tpu",))(
        *args).mlir_module()
    counts = check_hlo.summarize(txt)
    return {"available": True, "n_buckets": n_buckets,
            "fused_dispatches": counts["tpu_custom_call"],
            "stablehlo_collectives": sum(
                counts[op] for op in ("reduce_scatter", "all_gather",
                                      "collective_permute", "all_reduce")),
            "fused_dispatches_per_bucket":
                counts["tpu_custom_call"] / n_buckets}


# ---------------------------------------------------------------------------
# 3. wire bytes (plan statics)
# ---------------------------------------------------------------------------

def bench_wire_bytes():
    import jax
    from repro.core import plan as plan_lib
    per_worker = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
        _tree(N_WORKERS))
    plan = plan_lib.make_plan(per_worker, N_WORKERS, n_buckets=2)
    f32 = plan.wire_bytes("float32")
    bf16 = plan.wire_bytes("bfloat16")
    payload = plan.describe()["payload_bytes"]
    # RS leg = wire_bytes − AG leg (AG always moves the payload dtype)
    rs_f32, rs_bf16 = f32 - payload, bf16 - payload
    return {"wire_bytes_f32": int(f32), "wire_bytes_bf16": int(bf16),
            "rs_leg_bytes_f32": int(rs_f32),
            "rs_leg_bytes_bf16": int(rs_bf16),
            "rs_bytes_ratio_bf16_vs_f32": rs_bf16 / rs_f32}


# ---------------------------------------------------------------------------
# 4. peak memory: donated vs undonated ~100M simulator step (AOT)
# ---------------------------------------------------------------------------

def bench_sim_step_memory(quick):
    import dataclasses
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import channels as channels_lib
    from repro.core import plan as plan_lib
    from repro.optim import make_optimizer
    from repro.train import simulator as sim_lib

    n = 4
    if quick:
        d_model, n_layers, vocab = 256, 2, 2048
    else:
        d_model, n_layers, vocab = 768, 12, 32768   # ≈ 107M params

    shapes = {"emb": (vocab, d_model), "head": (d_model, vocab)}
    for i in range(n_layers):
        shapes[f"w1_{i}"] = (d_model, 4 * d_model)
        shapes[f"w2_{i}"] = (4 * d_model, d_model)
    n_params = sum(int(np.prod(v)) for v in shapes.values())

    def loss_fn(p, b):
        h = jnp.take(p["emb"], b, axis=0)
        for i in range(n_layers):
            h = jnp.tanh(h @ p[f"w1_{i}"]) @ p[f"w2_{i}"]
        logits = h @ p["head"]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, b[..., None], -1)[..., 0]
        return jnp.mean(logz - gold)

    def peak(scfg):
        params1 = {k: jax.ShapeDtypeStruct(v, jnp.float32)
                   for k, v in shapes.items()}
        opt = make_optimizer(scfg.optimizer)
        channel = channels_lib.make_channel(scfg.channel, n,
                                            scfg.drop_rate,
                                            s=scfg.n_servers)
        plan = plan_lib.plan_from_config(params1, n, scfg.n_servers,
                                         bucket_mb=scfg.bucket_mb,
                                         n_buckets=scfg.n_buckets)
        step = sim_lib.make_sim_step(loss_fn, scfg, channel, plan, opt)
        params = {k: jax.ShapeDtypeStruct((n,) + v, jnp.float32)
                  for k, v in shapes.items()}
        opt_state = jax.eval_shape(lambda: opt.init(params))
        batch = jax.ShapeDtypeStruct((n, 4, 64), jnp.int32)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        ch_state = jax.eval_shape(channel.init_state,
                                  jax.random.PRNGKey(0))
        ma = step.lower(params, opt_state, batch, key,
                        jax.ShapeDtypeStruct((), jnp.float32),
                        ch_state).compile().memory_analysis()
        return (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)

    base = sim_lib.SimulatorConfig(n_workers=n, drop_rate=DROP,
                                   aggregator="rps_model",
                                   channel=f"bernoulli:p={DROP}",
                                   n_buckets=2)
    p_on = peak(base)
    p_off = peak(dataclasses.replace(base, donate=False))
    return {"n_params": n_params, "n_workers": n,
            "peak_bytes_donated": int(p_on),
            "peak_bytes_undonated": int(p_off),
            "peak_memory_reduction": 1.0 - p_on / p_off}


# ---------------------------------------------------------------------------
# 5. single-device simulator exchange wall-clock per engine
# ---------------------------------------------------------------------------

def bench_global(reps, iters):
    import jax
    import jax.numpy as jnp
    from repro.core import plan as plan_lib
    from repro.core import rps as rps_lib
    tree = _tree(N_WORKERS)
    key = jax.random.PRNGKey(0)
    per_worker = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)
    plan = plan_lib.make_plan(per_worker, N_WORKERS, n_buckets=2)
    out = {}
    for name, engine, dt in (("xla_f32", "xla", jnp.float32),
                             ("ring_f32", "ring", jnp.float32),
                             ("ring_bf16", "ring", jnp.bfloat16)):
        fn = jax.jit(lambda t, k, e=engine, d=dt:
                     rps_lib.rps_exchange_global(
                         t, k, DROP, N_WORKERS, mode="model", plan=plan,
                         engine=e, rs_dtype=d))
        out[name] = _min_of_batches(fn, (tree, key), reps, iters) * 1e6
    return out


def run_bench(quick=False, out=None):
    import jax
    reps, iters = (2, 4) if quick else (5, 10)
    sched = bench_schedule(reps, max(3, iters // 2), quick)
    tpu = bench_tpu_export()
    wire = bench_wire_bytes()
    mem = bench_sim_step_memory(quick)
    glob_us = bench_global(reps, iters)

    result = {
        "backend": jax.default_backend(),
        "n_workers": N_WORKERS, "drop_rate": DROP,
        "schedule_ms": {k: round(v, 3) for k, v in sched["ms"].items()},
        "schedule_hlo": sched["hlo"],
        "tpu_export": tpu,
        "wire_bytes": wire,
        "sim_step_memory": mem,
        "simulator_exchange_us": {k: round(v, 1)
                                  for k, v in glob_us.items()},
        "quick": quick,
        "note": (
            "schedule_ms is measured on forced-host CPU devices, where "
            "the 'ring' engine is the interpret ppermute ring (2(n-1) "
            "sequential hops/bucket) and is expected to trail the xla "
            "engine's single fused collectives — wall-clock reported "
            "as-is, labelled by backend. The fused one-dispatch-per-"
            "bucket TPU lowering (where the ring overlaps RDMA with the "
            "masked accumulate) is validated via jax.export in "
            "tpu_export. rs_bytes_ratio_bf16_vs_f32 = 0.5: the bf16 "
            "wire halves the RS leg. peak_memory_reduction is the "
            "donate_argnums + copy-elimination win on the ~100M-param "
            "simulator step (AOT memory_analysis)."),
    }
    if out:                        # write before asserting: a failing run
        with open(out, "w") as f:  # still ships its data (CI artifact)
            json.dump(result, f, indent=1)
        print("wrote", out)
    # acceptance guards
    assert abs(wire["rs_bytes_ratio_bf16_vs_f32"] - 0.5) < 1e-6, wire
    assert mem["peak_memory_reduction"] >= 0.20, mem
    if tpu.get("available"):
        assert tpu["fused_dispatches_per_bucket"] == 1.0, tpu
        assert tpu["stablehlo_collectives"] == 0, tpu
    for nb in (1, 2):
        h = sched["hlo"][f"ring_b{nb}_f32"]
        assert h["collective_permute"] == 2 * (N_WORKERS - 1) * nb, h
        assert h["reduce_scatter"] == 0 and h["all_gather"] == 0, h
        hx = sched["hlo"][f"xla_b{nb}_f32"]
        assert hx["reduce_scatter"] == nb and hx["all_gather"] == nb, hx
    return result


def run(csv_rows, quick=True, engine=None):
    """benchmarks.run entry (engine accepted for CLI uniformity; this
    bench always measures both engines)."""
    res = run_bench(quick=quick)
    print(json.dumps(res, indent=1))
    for k, v in res["schedule_ms"].items():
        csv_rows.append((f"ring_schedule_{k}", v * 1e3,
                         f"backend={res['backend']}"))
    csv_rows.append(("ring_mem_reduction",
                     res["sim_step_memory"]["peak_memory_reduction"] * 100,
                     f"n_params={res['sim_step_memory']['n_params']}"))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizes (small model, few reps)")
    ap.add_argument("--out", default="BENCH_ring.json")
    args = ap.parse_args()
    res = run_bench(quick=args.quick, out=args.out)
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
