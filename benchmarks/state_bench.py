"""Quantized trainer-state benchmark (DESIGN.md §16): StatePack bytes,
peak step memory, and the packed-convergence cost.

Sections (all committed to ``BENCH_state.json``):

  1. **State bytes** (AOT shapes, exactly the dryrun accounting):
     per-component at-rest bytes for Adam under every pack on the
     ~107M-param bench model. Acceptance: the ``i8`` pack (m bf16,
     v int8 + per-row f32 scales) shrinks optimizer state ≥ 2x vs f32.
  2. **Peak step memory** (AOT ``memory_analysis`` on the donated
     simulator step, the ring_bench idiom: args + outputs + temps −
     aliased): adam + i8 pack vs adam + f32 pack on the same model.
     Acceptance: ≥ 10% peak reduction — the §16 point that once the
     params/state are donated, packing the state is the remaining lever.
  3. **Packed-convergence cost** (simulator, heterogeneous workers):
     the i8 pack's final-loss gap (vs the f32 pack, same f32 wire) must
     not exceed the int8 *wire* gap (vs the f32 wire, same f32 pack) at
     matching drop rate — SR on the EMA writes keeps the packed state's
     cost below the compression noise the study already accepts on the
     wire.

Run:  PYTHONPATH=src python -m benchmarks.state_bench [--quick] \
          [--out BENCH_state.json]
"""
import argparse
import json
import os

SRC = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
ROOT = os.path.dirname(SRC)

N_WORKERS = 4
PACKS = ("f32", "bf16", "i8")


def _bench_model(quick):
    import numpy as np
    if quick:
        d_model, n_layers, vocab = 256, 2, 2048
    else:
        d_model, n_layers, vocab = 768, 12, 32768   # ≈ 107M params
    shapes = {"emb": (vocab, d_model), "head": (d_model, vocab)}
    for i in range(n_layers):
        shapes[f"w1_{i}"] = (d_model, 4 * d_model)
        shapes[f"w2_{i}"] = (4 * d_model, d_model)
    n_params = sum(int(np.prod(v)) for v in shapes.values())

    def loss_fn(p, b):
        import jax
        import jax.numpy as jnp
        h = jnp.take(p["emb"], b, axis=0)
        for i in range(n_layers):
            h = jnp.tanh(h @ p[f"w1_{i}"]) @ p[f"w2_{i}"]
        logits = h @ p["head"]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, b[..., None], -1)[..., 0]
        return jnp.mean(logz - gold)

    return shapes, n_params, loss_fn


# ---------------------------------------------------------------------------
# 1. at-rest state bytes per pack (AOT shapes — the dryrun accounting)
# ---------------------------------------------------------------------------

def bench_state_bytes(quick):
    import jax
    import jax.numpy as jnp
    from repro.optim import make_optimizer
    from repro.optim import statepack as statepack_lib

    shapes, n_params, _ = _bench_model(quick)
    params = {k: jax.ShapeDtypeStruct(v, jnp.float32)
              for k, v in shapes.items()}
    out = {"n_params": n_params,
           "param_bytes": statepack_lib.tree_bytes(params)}
    for pk in PACKS:
        opt = make_optimizer("adam", state_pack=pk)
        st = jax.eval_shape(opt.init, params)
        bd = statepack_lib.state_bytes_breakdown(opt_state=st)
        ef = jax.eval_shape(
            lambda p: statepack_lib.pack_tree(
                jax.tree.map(jnp.zeros_like, p),
                statepack_lib.make_state_pack(pk).ef_format), params)
        bd.update({f"ef_{k}": v for k, v in
                   statepack_lib.state_bytes_breakdown(
                       ef_state=ef).items() if k != "total"})
        out[pk] = bd
    opt_bytes = {pk: sum(v for k, v in out[pk].items()
                         if k.startswith("opt_")) for pk in PACKS}
    out["opt_bytes"] = opt_bytes
    out["opt_bytes_ratio_f32_over_i8"] = opt_bytes["f32"] / opt_bytes["i8"]
    return out


# ---------------------------------------------------------------------------
# 2. peak donated-step memory: adam f32 pack vs i8 pack (AOT analysis)
# ---------------------------------------------------------------------------

def bench_step_memory(quick):
    import jax
    import jax.numpy as jnp
    from repro import channels as channels_lib
    from repro.core import plan as plan_lib
    from repro.optim import make_optimizer
    from repro.optim import statepack as statepack_lib
    from repro.train import simulator as sim_lib

    n = N_WORKERS
    shapes, n_params, loss_fn = _bench_model(quick)

    def peak(pack):
        scfg = sim_lib.SimulatorConfig(
            n_workers=n, drop_rate=0.1, aggregator="rps_model",
            n_buckets=2, optimizer="adam", state_pack=pack,
            wire="int8", recovery="ef")
        params1 = {k: jax.ShapeDtypeStruct(v, jnp.float32)
                   for k, v in shapes.items()}
        opt = make_optimizer("adam", state_pack=pack)
        channel = channels_lib.make_channel(scfg.channel, n,
                                            scfg.drop_rate)
        plan = plan_lib.plan_from_config(params1, n, n_buckets=2,
                                         wire="int8", recovery="ef")
        step = sim_lib.make_sim_step(loss_fn, scfg, channel, plan, opt)
        params = {k: jax.ShapeDtypeStruct((n,) + v, jnp.float32)
                  for k, v in shapes.items()}
        opt_state = jax.eval_shape(lambda: opt.init(params))
        ef_state = jax.eval_shape(
            lambda: statepack_lib.pack_tree(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             params),
                statepack_lib.make_state_pack(pack).ef_format))
        batch = jax.ShapeDtypeStruct((n, 4, 64), jnp.int32)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        ch_state = jax.eval_shape(channel.init_state,
                                  jax.random.PRNGKey(0))
        ma = step.lower(params, opt_state, batch, key,
                        jax.ShapeDtypeStruct((), jnp.float32),
                        ch_state, ef_state).compile().memory_analysis()
        return (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes)

    p_f32 = peak("f32")
    p_i8 = peak("i8")
    return {"n_params": n_params, "n_workers": n,
            "peak_bytes_f32_pack": int(p_f32),
            "peak_bytes_i8_pack": int(p_i8),
            "peak_memory_reduction": 1.0 - p_i8 / p_f32}


# ---------------------------------------------------------------------------
# 3. packed convergence vs the wire-compression budget
# ---------------------------------------------------------------------------

def _task(n, seed=0):
    import jax
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(seed)
    xs = jnp.asarray(rng.normal(size=(n, 16, 6)), jnp.float32)
    w_true = jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)
    ys = xs @ w_true

    def init_fn(key):
        return {"w": jax.random.normal(key, (6, 4)) * 0.1}

    def loss_fn(p, b):
        x, y = b
        return jnp.mean((x @ p["w"] - y) ** 2)

    return loss_fn, init_fn, lambda t: (xs, ys)


def bench_convergence(quick):
    import numpy as np
    from repro.train.simulator import SimulatorConfig, run_simulation

    steps = 80 if quick else 200
    seeds = (0,) if quick else (0, 1, 2)
    ps = (0.2,) if quick else (0.1, 0.2, 0.3)

    def final(wire, pack, p, seed):
        loss_fn, init_fn, batch_fn = _task(N_WORKERS, seed=seed)
        h = run_simulation(loss_fn, init_fn, batch_fn, SimulatorConfig(
            n_workers=N_WORKERS, drop_rate=p, aggregator="rps_model",
            steps=steps, lr=0.05, warmup=5, n_buckets=2, seed=seed,
            optimizer="adam", state_pack=pack, wire=wire,
            recovery="ef"))
        return h["final_loss"]

    rows = []
    for p in ps:
        base = float(np.mean([final("f32", "f32", p, s) for s in seeds]))
        wire8 = float(np.mean([final("int8", "f32", p, s) for s in seeds]))
        pack8 = float(np.mean([final("f32", "i8", p, s) for s in seeds]))
        both8 = float(np.mean([final("int8", "i8", p, s) for s in seeds]))
        rows.append({"p": p, "loss_f32wire_f32pack": base,
                     "loss_int8wire_f32pack": wire8,
                     "loss_f32wire_i8pack": pack8,
                     "loss_int8wire_i8pack": both8,
                     "wire_gap": wire8 - base,
                     "pack_gap": pack8 - base})
    return {"steps": steps, "seeds": len(seeds), "rows": rows}


def run_bench(quick=False, out=None):
    import jax
    sb = bench_state_bytes(quick)
    mem = bench_step_memory(quick)
    conv = bench_convergence(quick)
    result = {
        "backend": jax.default_backend(),
        "n_workers": N_WORKERS,
        "state_bytes": sb,
        "step_memory": mem,
        "convergence": conv,
        "quick": quick,
        "note": (
            "state_bytes is the at-rest accounting on AOT shapes (the "
            "dryrun report path); opt_bytes_ratio_f32_over_i8 is the "
            "headline >=2x Adam-state claim. step_memory is the "
            "donated simulator step's AOT memory_analysis (args + "
            "outputs + temps - aliased) with adam+EF, f32 vs i8 pack. "
            "convergence compares the i8 pack's final-loss gap on an "
            "f32 wire against the int8 wire's gap on an f32 pack at "
            "the same drop rate — the pack must cost no more than the "
            "wire compression the study already budgets for (a small "
            "absolute tolerance absorbs seed noise on the toy task)."),
    }
    if out:                        # write before asserting: a failing run
        with open(out, "w") as f:  # still ships its data (CI artifact)
            json.dump(result, f, indent=1)
        print("wrote", out)
    # acceptance guards
    assert sb["opt_bytes_ratio_f32_over_i8"] >= 2.0, sb
    assert mem["peak_memory_reduction"] >= 0.10, mem
    for row in conv["rows"]:
        assert row["pack_gap"] <= row["wire_gap"] + 0.02, row
    return result


def run(csv_rows, quick=True, engine=None):
    """benchmarks.run entry (engine accepted for CLI uniformity)."""
    del engine
    res = run_bench(quick=quick)
    print(json.dumps(res, indent=1))
    csv_rows.append(("state_opt_bytes_ratio", 0.0,
                     f"f32/i8={res['state_bytes']['opt_bytes_ratio_f32_over_i8']:.2f}"))
    csv_rows.append(("state_peak_mem_reduction",
                     res["step_memory"]["peak_memory_reduction"] * 100,
                     f"n_params={res['step_memory']['n_params']}"))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small model, fewer seeds/steps")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    res = run_bench(quick=args.quick, out=args.out)
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
