"""Kernel microbenchmarks: Pallas (interpret — correctness-path timing only
on CPU) and the XLA production paths vs the sequential references. On real
TPU hardware the pallas path is the hot one; here we report CPU us/call for
the XLA paths and verify the kernels still agree at bench shapes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.telemetry.timing import time_fn


def _time(fn, *args, reps=5):
    # the unified repo timer (DESIGN.md §14): compile + warmup, best of
    # `reps` synced batches, µs/call
    return time_fn(fn, *args, reps=reps, iters=1) * 1e6


def run(csv_rows):
    rng = np.random.default_rng(0)
    print("# kernel microbench (CPU; pallas validated in interpret mode)")
    # masked_avg
    n, d = 32, 1 << 20
    blocks = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    mask = jnp.asarray(rng.integers(0, 2, size=n), jnp.float32).at[0].set(1)
    f = jax.jit(lambda b, m: ops.masked_avg(b, m, backend="ref"))
    us = _time(f, blocks, mask)
    print(f"masked_avg xla n={n} d={d}: {us:.0f} us")
    csv_rows.append(("masked_avg_xla", us, f"n={n};d={d}"))

    # rwkv6 chunked XLA
    B, S, h, dk = 4, 512, 8, 64
    r = jnp.asarray(rng.normal(size=(B, S, h, dk)) * .5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, h, dk)) * .5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, h, dk)) * .5, jnp.float32)
    w = jnp.asarray(rng.uniform(.2, .99, size=(B, S, h, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(h, dk)) * .1, jnp.float32)
    fx = jax.jit(lambda *a: ops.rwkv6(*a, backend="xla"))
    us = _time(fx, r, k, v, w, u)
    print(f"rwkv6 xla B{B} S{S} h{h} dk{dk}: {us:.0f} us")
    csv_rows.append(("rwkv6_xla", us, f"B={B};S={S}"))
    got = np.asarray(fx(r, k, v, w, u))
    want = np.asarray(ref.rwkv6_ref(r, k, v, w, u))
    assert np.allclose(got, want, atol=1e-3), "rwkv6 bench shape mismatch"

    # rglru associative-scan XLA
    x = jnp.asarray(rng.normal(size=(4, 2048, 512)), jnp.float32)
    a = jnp.asarray(rng.uniform(.1, .999, size=(4, 2048, 512)), jnp.float32)
    fg = jax.jit(lambda *args: ops.rglru(*args, backend="xla")[0])
    us = _time(fg, x, a)
    print(f"rglru assoc-scan B4 S2048 d512: {us:.0f} us")
    csv_rows.append(("rglru_xla", us, "B=4;S=2048;d=512"))
