"""Count collectives / fused dispatches / fusions in lowered programs.

Schedule regressions are silent: a refactor that re-serialises the
exchange (2 collectives per *leaf* instead of per bucket, a fused ring
dispatch that falls apart into its pieces) still trains correctly — only
slower. This module is the loud failure: tests and CI lower the program
and assert the op counts.

Works on both program texts the repo produces:

  - StableHLO MLIR from ``jax.jit(f).lower(...).as_text()`` or
    ``jax.export`` — ops like ``stablehlo.reduce_scatter``, and Pallas
    TPU kernels as ``stablehlo.custom_call`` with
    ``call_target_name = "tpu_custom_call"`` (one per fused dispatch);
  - optimized HLO from ``.compile().as_text()`` — dashed op names
    (``all-gather``, ``collective-permute``) and ``fusion`` ops.

CLI (used by the CI bench-smoke job)::

  PYTHONPATH=src:. python -m tools.check_hlo prog.mlir \
      --expect reduce_scatter=2 --expect all_gather=2

reads the program text (or stdin with ``-``) and exits non-zero on any
mismatch.
"""
from __future__ import annotations

import argparse
import re
import sys
from typing import Dict

#: op keys understood by :func:`collective_counts`
COLLECTIVE_OPS = ("reduce_scatter", "all_gather", "collective_permute",
                  "all_reduce", "all_to_all")


def _count_op(txt: str, op: str) -> int:
    """Occurrences of one collective op, StableHLO or optimized-HLO
    spelling. Counts op *applications* only — substring counting would
    also hit attributes like ``all_gather_dim``."""
    n = len(re.findall(r'"stablehlo\.%s"\(' % re.escape(op), txt))
    n += len(re.findall(r'stablehlo\.%s\s' % re.escape(op), txt))
    dashed = op.replace("_", "-")
    # optimized HLO: `%x = f32[...] all-gather(...)` (incl. -start/-done
    # async pairs, counted once via -start; bare form for sync ops)
    n += len(re.findall(r'= \S+ %s\(' % re.escape(dashed), txt))
    n += len(re.findall(r'= \S+ %s-start\(' % re.escape(dashed), txt))
    return n


def collective_counts(txt: str) -> Dict[str, int]:
    """{op: count} over :data:`COLLECTIVE_OPS` for a lowered/compiled
    program text."""
    return {op: _count_op(txt, op) for op in COLLECTIVE_OPS}


def fused_dispatch_count(txt: str) -> int:
    """Pallas-TPU fused dispatches: custom calls targeting
    ``tpu_custom_call`` (one per ``pallas_call`` — the quantity the ring
    engine pins to 1 per bucket)."""
    return txt.count("tpu_custom_call")


def fusion_count(txt: str) -> int:
    """XLA ``fusion`` ops in an optimized-HLO text (0 for StableHLO —
    fusion happens after lowering). One pattern only: the op application
    ``%name = <shape> fusion(...)`` — matching the result name too would
    double-count results named ``%fusion.N``."""
    return len(re.findall(r"= \S+ fusion(?:\.\d+)?\(", txt))


def assert_fused_per_bucket(txt: str, n_buckets: int,
                            per_bucket: int = 1) -> int:
    """Assert the fused-dispatch *density* of a lowered ring round:
    exactly ``per_bucket`` (default 1) ``tpu_custom_call`` per bucket and
    zero StableHLO collectives — the §12/§13 claim that neither bucketing
    nor any wire codec (bf16, int8 with its in-kernel decode + hop
    requantisation) adds a dispatch. Returns the dispatch count."""
    got = fused_dispatch_count(txt)
    want = int(n_buckets) * int(per_bucket)
    if got != want:
        raise AssertionError(
            f"fused dispatches: got {got}, want {want} "
            f"({per_bucket}/bucket × {n_buckets} buckets)")
    colls = {k: v for k, v in collective_counts(txt).items() if v}
    if colls:
        raise AssertionError(
            f"fused ring round leaked StableHLO collectives: {colls}")
    return got


def summarize(txt: str) -> Dict[str, int]:
    out = dict(collective_counts(txt))
    out["tpu_custom_call"] = fused_dispatch_count(txt)
    out["fusion"] = fusion_count(txt)
    return out


def assert_counts(txt: str, **expected: int) -> Dict[str, int]:
    """Assert exact op counts (keys from :func:`summarize`); returns the
    full summary so callers can log it."""
    got = summarize(txt)
    bad = {k: (got.get(k), v) for k, v in expected.items()
           if got.get(k) != v}
    if bad:
        raise AssertionError(
            "HLO op-count mismatch (got, want): " + repr(bad)
            + " | full summary: " + repr(got))
    return got


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="program text file, or - for stdin")
    ap.add_argument("--expect", action="append", default=[],
                    metavar="OP=N",
                    help="assert op count (repeatable), e.g. "
                         "--expect all_gather=2 --expect tpu_custom_call=1")
    args = ap.parse_args()
    txt = sys.stdin.read() if args.path == "-" else open(args.path).read()
    expected = {}
    for e in args.expect:
        op, _, v = e.partition("=")
        expected[op] = int(v)
    try:
        got = assert_counts(txt, **expected)
    except AssertionError as e:
        print("FAIL:", e)
        sys.exit(1)
    print(" ".join(f"{k}={v}" for k, v in got.items()))


if __name__ == "__main__":
    main()
