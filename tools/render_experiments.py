"""Render experiment artifacts for humans.

Two modes:

  # EXPERIMENTS.md roofline tables from results/dryrun_*.json (legacy)
  python tools/render_experiments.py results/dryrun_baseline.json

  # standalone HTML report from a --telemetry-dir run (DESIGN.md §14)
  python tools/render_experiments.py --telemetry DIR [--html out.html]

The telemetry report shows the run context (plan, channel, α bounds),
the per-link observed-vs-expected drop-rate table with the drift
verdict, loss / drop-rate sparklines over the recorded steps, and the
unified bench-timing table — all from summary.json + telemetry.jsonl,
no dependencies beyond the stdlib.
"""
import argparse
import html
import json
import os
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def rows(results, mesh):
    out = []
    for r in sorted(results, key=lambda r: (r["arch"],
                                            ORDER.index(r["shape"]))):
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — | — |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['t_compute']*1e3:.2f} | {rf['t_memory']*1e3:.2f} "
            f"| {rf['t_collective']*1e3:.2f} | **{rf['bottleneck']}** "
            f"| {rf['useful_ratio']:.2f} "
            f"| {rf['hbm_per_device']/1e9:.1f} "
            f"| {'yes' if rf['fits'] else 'NO'} |")
    return out


def main_dryrun(path):
    with open(path) as f:
        results = json.load(f)
    hdr = ("| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck "
           "| useful | HBM GB/dev | fits 16GB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh}\n")
        print(hdr)
        print("\n".join(rows(results, mesh)))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum("skipped" in str(r["status"]) for r in results)
    print(f"\n{ok} ok / {skip} skipped / {len(results)-ok-skip} failed "
          f"of {len(results)}")


# ---------------------------------------------------------------------------
# telemetry HTML report
# ---------------------------------------------------------------------------

_CSS = """
body { font: 14px/1.5 system-ui, sans-serif; margin: 2em auto;
       max-width: 62em; color: #1b1f24; }
h1 { font-size: 1.4em; } h2 { font-size: 1.1em; margin-top: 1.6em; }
table { border-collapse: collapse; margin: .6em 0; }
th, td { border: 1px solid #d0d7de; padding: .25em .6em;
         text-align: right; font-variant-numeric: tabular-nums; }
th { background: #f6f8fa; }
td.l, th.l { text-align: left; }
.ok { color: #1a7f37; } .bad { color: #cf222e; font-weight: 600; }
.meta { color: #57606a; }
svg { background: #f6f8fa; border: 1px solid #d0d7de; }
"""


def _sparkline(vals, width=480, height=64, color="#0969da"):
    """Inline SVG polyline of a numeric series (min-max scaled)."""
    vals = [float(v) for v in vals
            if v is not None and v == v]            # drop None/NaN
    if len(vals) < 2:
        return "<p class=meta>not enough points</p>"
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    pad = 4
    pts = " ".join(
        f"{pad + i * (width - 2 * pad) / (len(vals) - 1):.1f},"
        f"{height - pad - (v - lo) * (height - 2 * pad) / span:.1f}"
        for i, v in enumerate(vals))
    return (f'<svg width="{width}" height="{height}" '
            f'viewBox="0 0 {width} {height}">'
            f'<polyline fill="none" stroke="{color}" stroke-width="1.5" '
            f'points="{pts}"/></svg>'
            f'<div class=meta>first={vals[0]:.4g} last={vals[-1]:.4g} '
            f'min={lo:.4g} max={hi:.4g} ({len(vals)} points)</div>')


def _link_table(link):
    """Per-link observed-vs-expected table from a drift() dict."""
    obs, exp = link["observed_p"], link["expected_p"]
    se, tol = link["stderr"], link["tolerance"]
    drifted = link["drifted"]
    pkts = link["packets"]
    out = ["<table><tr><th class=l>link</th><th>observed p</th>"
           "<th>expected p</th><th>stderr</th><th>tolerance</th>"
           "<th>packets</th><th class=l>verdict</th></tr>"]
    for i in range(len(obs)):
        cls = "bad" if drifted[i] else "ok"
        word = "DRIFT" if drifted[i] else "ok"
        out.append(
            f"<tr><td class=l>{i}</td><td>{obs[i]:.4f}</td>"
            f"<td>{exp[i]:.4f}</td><td>{se[i]:.4f}</td>"
            f"<td>{tol[i]:.4f}</td><td>{pkts[i]:.0f}</td>"
            f"<td class='l {cls}'>{word}</td></tr>")
    out.append("</table>")
    return "".join(out)


def render_telemetry_html(tel_dir):
    """Build the HTML report string from a --telemetry-dir directory."""
    with open(os.path.join(tel_dir, "summary.json")) as f:
        summ = json.load(f)
    records = []
    jsonl = os.path.join(tel_dir, "telemetry.jsonl")
    if os.path.exists(jsonl):
        with open(jsonl) as f:
            records = [json.loads(line) for line in f if line.strip()]

    meta = summ.get("meta", {})
    parts = ["<!doctype html><meta charset=utf-8>",
             "<title>exchange telemetry report</title>",
             f"<style>{_CSS}</style>",
             "<h1>Exchange telemetry report</h1>"]

    # run context
    parts.append("<h2>Run context</h2><table>")
    for k in ("n", "p", "channel", "aggregator"):
        if k in meta:
            parts.append(f"<tr><th class=l>{k}</th><td class=l>"
                         f"{html.escape(str(meta[k]))}</td></tr>")
    plan = meta.get("plan")
    if plan:
        parts.append(
            f"<tr><th class=l>plan</th><td class=l>"
            f"{plan.get('n_buckets')} buckets × s={plan.get('s')}, "
            f"wire={plan.get('wire')}/{plan.get('recovery')}, "
            f"payload={plan.get('payload_bytes', 0):,} B</td></tr>")
    ab = meta.get("alpha_bounds")
    if ab:
        parts.append(
            f"<tr><th class=l>α bounds (theory)</th><td class=l>"
            f"α₁={ab['alpha1']:.4f}, α₂={ab['alpha2']:.4f}</td></tr>")
    parts.append(f"<tr><th class=l>steps recorded</th>"
                 f"<td class=l>{summ.get('steps', 0)}</td></tr></table>")

    # per-link drift
    link = summ.get("link_p")
    if link:
        for leg, title in (("rs", "Reduce-scatter leg"),
                           ("ag", "All-gather leg")):
            d = link.get(leg)
            if not d:
                continue
            verdict = ("<span class=bad>DRIFT DETECTED</span>"
                       if d["any_drift"] else
                       "<span class=ok>within tolerance</span>")
            parts.append(
                f"<h2>Per-link delivery — {title}</h2>"
                f"<p>Observed effective drop rate per link vs the "
                f"configured channel: {verdict} "
                f"(max |dev| = {d['max_abs_dev']:.4f}).</p>")
            parts.append(_link_table(d))
    else:
        parts.append("<h2>Per-link delivery</h2><p class=meta>no link "
                     "counters in this run (non-RPS aggregator or no "
                     "exchange).</p>")

    # step series
    if records:
        parts.append("<h2>Step series</h2>")
        for key, label in (("loss", "loss"),
                           ("rs_drop_rate", "realized RS drop rate"),
                           ("grad_norm", "gradient norm"),
                           ("consensus", "consensus distance")):
            vals = [r.get(key) for r in records if r.get(key) is not None]
            if vals:
                parts.append(f"<h3>{label}</h3>{_sparkline(vals)}")

    # timings
    tim = summ.get("timings_s")
    if tim:
        parts.append("<h2>Timings</h2><table><tr><th class=l>label</th>"
                     "<th>best ms</th><th>mean ms</th><th>n</th></tr>")
        for k in sorted(tim):
            v = tim[k]
            parts.append(f"<tr><td class=l>{html.escape(k)}</td>"
                         f"<td>{v['best']*1e3:.3f}</td>"
                         f"<td>{v['mean']*1e3:.3f}</td>"
                         f"<td>{v['n']}</td></tr>")
        parts.append("</table>")

    parts.append("<p class=meta>Generated by "
                 "tools/render_experiments.py --telemetry; trace.json in "
                 "the same directory loads in Perfetto / "
                 "chrome://tracing.</p>")
    return "\n".join(parts)


def main_telemetry(tel_dir, html_out=None):
    doc = render_telemetry_html(tel_dir)
    out = html_out or os.path.join(tel_dir, "report.html")
    with open(out, "w") as f:
        f.write(doc)
    print("report ->", out)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", nargs="?", default=None,
                    help="dryrun results JSON (legacy roofline mode)")
    ap.add_argument("--telemetry", default=None, metavar="DIR",
                    help="render an HTML report from a --telemetry-dir "
                         "directory (summary.json + telemetry.jsonl)")
    ap.add_argument("--html", default=None,
                    help="output path for the telemetry report "
                         "(default: DIR/report.html)")
    args = ap.parse_args(argv)
    if args.telemetry:
        main_telemetry(args.telemetry, args.html)
    else:
        main_dryrun(args.path or "results/dryrun_baseline.json")


if __name__ == "__main__":
    main()
