"""Render results/dryrun_*.json into the EXPERIMENTS.md roofline tables."""
import json
import sys

ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def rows(results, mesh):
    out = []
    for r in sorted(results, key=lambda r: (r["arch"],
                                            ORDER.index(r["shape"]))):
        if r["mesh"] != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — | — |")
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {rf['t_compute']*1e3:.2f} | {rf['t_memory']*1e3:.2f} "
            f"| {rf['t_collective']*1e3:.2f} | **{rf['bottleneck']}** "
            f"| {rf['useful_ratio']:.2f} "
            f"| {rf['hbm_per_device']/1e9:.1f} "
            f"| {'yes' if rf['fits'] else 'NO'} |")
    return out


def main(path):
    with open(path) as f:
        results = json.load(f)
    hdr = ("| arch | shape | t_comp ms | t_mem ms | t_coll ms | bottleneck "
           "| useful | HBM GB/dev | fits 16GB |\n"
           "|---|---|---|---|---|---|---|---|---|")
    for mesh in ("16x16", "2x16x16"):
        print(f"\n### Mesh {mesh}\n")
        print(hdr)
        print("\n".join(rows(results, mesh)))
    ok = sum(r["status"] == "ok" for r in results)
    skip = sum("skipped" in str(r["status"]) for r in results)
    print(f"\n{ok} ok / {skip} skipped / {len(results)-ok-skip} failed "
          f"of {len(results)}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.json")
