#!/usr/bin/env bash
# Host-perf launcher (DESIGN.md §16, SNIPPETS run.sh exemplars).
#
# Wraps any repo command with the host hygiene the benches and
# multi-host-on-CPU parity runs need:
#   - tcmalloc LD_PRELOAD when the library is installed (glibc malloc
#     fragments under XLA's large transient allocations);
#   - --xla_force_host_platform_device_count derived from the command's
#     own --workers flag (one XLA host device per simulated worker);
#   - step-marker flags for host-profile step attribution.
#
# Usage:
#   ./run.sh python -m repro.launch.train --workers 16 --steps 200
#   ./run.sh python -m repro.launch.dryrun --arch llama3_405b --shape train_4k
#   RUN_SH_WORKERS=8 ./run.sh python -m pytest tests/test_trainer.py
set -euo pipefail

cd "$(dirname "$0")"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "$#" -eq 0 ]; then
  echo "usage: $0 <command …>   (e.g. $0 python -m repro.launch.train --workers 16)" >&2
  exit 2
fi

# the env module computes the preamble; RUN_SH_WORKERS overrides the
# command's own --workers for commands that don't take the flag
preamble="$(python3 -m repro.launch.env ${RUN_SH_WORKERS:+--workers "$RUN_SH_WORKERS"} -- "$@")"
eval "$preamble"

exec "$@"
