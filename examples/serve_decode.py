"""Batched serving demo: prefill + KV-cache decode across the model zoo
(reduced configs), including the attention-free and hybrid families.

  PYTHONPATH=src python examples/serve_decode.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.serve import ServeEngine


def main():
    rng = np.random.default_rng(0)
    for arch in ("gemma3-1b", "rwkv6-1.6b", "recurrentgemma-9b",
                 "mixtral-8x22b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg, grouped=False)
        params = model.init(jax.random.PRNGKey(0))
        eng = ServeEngine(model=model, params=params, max_len=96)
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, size=(4, 64)), jnp.int32)
        t0 = time.time()
        out = eng.generate(prompts, n_new=16)
        dt = time.time() - t0
        print(f"{arch:20s} generated {out.shape} "
              f"({4 * 16 / dt:6.1f} tok/s CPU) head: {np.asarray(out[0, :6])}")


if __name__ == "__main__":
    main()
