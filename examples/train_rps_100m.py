"""End-to-end driver: train a ~100M-parameter GQA transformer for a few
hundred steps with RPS aggregation over unreliable workers.

  PYTHONPATH=src python examples/train_rps_100m.py [--steps 300] [--p 0.1]

This is the "real" training path: the full model zoo stack (scan-over-layers
+ remat), the synthetic data pipeline, the paper's SGD + warmup recipe,
periodic checkpointing, and the RPS exchange each step. On CPU it uses 4
workers and a shortened run by default; pass --paper-scale for n=16.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.configs.base import ArchConfig
from repro.data.synthetic import CharLMTask, make_worker_streams
from repro.models import build_model
from repro.train.simulator import SimulatorConfig, run_simulation

# ~100M params: 12L, d=768, vocab 16k -> 12·(4·768² + 3·768·3072) + 2·16k·768
CFG_100M = ArchConfig(
    name="rps-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, d_ff=3072, vocab_size=16_384, max_seq=1024,
    dtype="float32", citation="this-repo demo")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--p", type=float, default=0.1)
    ap.add_argument("--channel", default=None,
                    help="drop-process spec (repro.channels), e.g. "
                         "'ge:p_bad=1,burst=8,p=0.1' or "
                         "'trace:lam=8000,prio=0.8'; default "
                         "i.i.d. Bernoulli(--p)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--paper-scale", action="store_true",
                    help="n=16 workers, batch 32 (slow on CPU)")
    ap.add_argument("--ckpt", default="/tmp/rps_100m.npz")
    args = ap.parse_args()
    if args.paper_scale:
        args.workers, args.batch_size = 16, 32

    cfg = CFG_100M
    model = build_model(cfg, grouped=True)
    n_params = sum(x.size for x in jax.tree.leaves(
        jax.eval_shape(model.init, jax.random.PRNGKey(0))))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params, "
          f"n={args.workers} workers, p={args.p}")

    task = CharLMTask(vocab=cfg.vocab_size, seq_len=args.seq_len, seed=0)
    batch_fn = make_worker_streams(task, args.workers, args.batch_size)

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    scfg = SimulatorConfig(n_workers=args.workers, drop_rate=args.p,
                           aggregator="rps_model", lr=0.3, warmup=20,
                           steps=args.steps, eval_every=20,
                           channel=args.channel)
    t0 = time.time()
    h = run_simulation(loss_fn, model.init, batch_fn, scfg)
    dt = time.time() - t0
    print("step  loss      consensus")
    for s, l, c in zip(h["step"], h["loss"], h["consensus"]):
        print(f"{s:5d} {l:9.4f} {c:.3e}")
    print(f"final loss {h['final_loss']:.4f} "
          f"(floor {task.entropy_floor():.4f}) in {dt:.0f}s")
    mean_params = jax.tree.map(lambda x: jnp.mean(x, 0), h["params"])
    save_pytree(args.ckpt, mean_params)
    print("checkpoint ->", args.ckpt)
    assert h["loss"][-1] < h["loss"][0], "loss should decrease"


if __name__ == "__main__":
    main()
