"""Quickstart: train a tiny LM with RPS over 16 simulated unreliable workers.

  PYTHONPATH=src python examples/quickstart.py

Shows the paper's three headline behaviours in ~a minute on CPU:
  1. RPS at a 10% packet-drop rate matches the reliable baseline.
  2. Naive gradient averaging at the same drop rate does worse.
  3. The closed-form α₂ bound predicts the (tiny) consensus error.
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import theory
from repro.data.synthetic import TeacherTask, make_worker_streams
from repro.train.simulator import SimulatorConfig, run_simulation

N_WORKERS, STEPS, DROP = 16, 150, 0.1


def main():
    task = TeacherTask(d_in=24, n_classes=8, hetero=0.3, seed=0)

    def init_fn(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (24, 48)) * 0.1,
                "w2": jax.random.normal(k2, (48, 8)) * 0.1}

    def loss_fn(p, batch):
        x, y = batch
        h = jnp.tanh(x @ p["w1"])
        logits = h @ p["w2"]
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
        return jnp.mean(logz - gold)

    batch_fn = make_worker_streams(task, N_WORKERS, 32)
    print("task: heterogeneous teacher-student classification, n=16 workers")
    print(f"theory: alpha2 bound at (n={N_WORKERS}, p={DROP}) = "
          f"{theory.alpha2_bound(N_WORKERS, DROP):.4f} (O(p(1-p)/n))\n")

    results = {}
    for name, agg, p in [("reliable baseline", "allreduce_model", 0.0),
                         ("RPS, 10% drops", "rps_model", DROP),
                         ("grad-avg, 10% drops", "rps_grad", DROP)]:
        h = run_simulation(loss_fn, init_fn, batch_fn,
                           SimulatorConfig(n_workers=N_WORKERS, drop_rate=p,
                                           aggregator=agg, lr=0.2, warmup=10,
                                           steps=STEPS, eval_every=STEPS - 1))
        results[name] = h
        print(f"{name:22s} final_loss={h['final_loss']:.4f} "
              f"consensus={h['consensus'][-1]:.2e}")

    assert results["RPS, 10% drops"]["final_loss"] < \
        results["reliable baseline"]["final_loss"] * 1.15 + 0.02
    print("\nRPS under 10% drops ≈ reliable baseline — the paper's claim.")


if __name__ == "__main__":
    main()
