"""§7 case study: how much faster does a colocated Web service get when the
RPS learning traffic tolerates drops — and does the model still converge at
that drop rate? Joins the netsim curve with a convergence run at the induced
drop rate.

  PYTHONPATH=src python examples/colocation_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.data.synthetic import CharLMTask, make_worker_streams
from repro.models import build_model
from repro.netsim import NetConfig, speedup_curve
from repro.train.simulator import SimulatorConfig, run_simulation


def main():
    ncfg = NetConfig(sim_s=1.0)
    lam = 5000
    pts = speedup_curve(lam, prios=(0.0, 0.25, 0.5, 1.0), cfg=ncfg)
    print(f"web load λ={lam}/s over 16×1Gbps, learning 2.4 Gbps bursts")
    print("prio  learn_drop  web_ms   speedup")
    for pt in pts:
        print(f"{pt['prio']:4.2f}  {pt['learning_drop_frac']:9.3f}  "
              f"{pt['avg_completion_ms']:6.2f}  {pt['speedup']:6.2f}x")

    # pick the operating point nearest 10% drops and check convergence there
    op = min(pts, key=lambda r: abs(r["learning_drop_frac"] - 0.10))
    p = op["learning_drop_frac"]
    print(f"\noperating point: drop={p:.3f} -> web speedup "
          f"{op['speedup']:.2f}x. Training at this drop rate:")

    cfg = get_config("rps-paper-mlp")
    model = build_model(cfg, grouped=False)
    task = CharLMTask(vocab=cfg.vocab_size, seq_len=48, seed=0)
    batch_fn = make_worker_streams(task, 16, 32)

    def loss_fn(params, b):
        return model.loss(params, b)[0]

    for pp, agg in [(0.0, "allreduce_model"), (p, "rps_model")]:
        h = run_simulation(loss_fn, model.init, batch_fn,
                           SimulatorConfig(n_workers=16, drop_rate=pp,
                                           aggregator=agg, lr=0.5, warmup=10,
                                           steps=120, eval_every=119))
        print(f"  p={pp:.3f} {agg:16s} final_loss={h['final_loss']:.4f}")
    print("\nconclusion: the web service gains "
          f"{(op['speedup'] - 1) * 100:.0f}% while training is unaffected.")


if __name__ == "__main__":
    main()
